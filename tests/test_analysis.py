"""Tests for the repro.analysis subsystem: structural verifier,
dataflow passes, secret-flow/jit linters, CLI exit codes, baseline
ratchet, and the hardened Bristol import path."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import (
    Baseline,
    Finding,
    NetlistError,
    analyze_netlist,
    verify_netlist,
    verify_netlist_strict,
)
from repro.analysis import cli as lint_cli
from repro.analysis.jit_hygiene import run_jit_hygiene
from repro.analysis.netcheck import generator_registry, run_netcheck
from repro.analysis.secretflow import lint_file as sf_lint_file
from repro.analysis.secretflow import run_secretflow
from repro.core.circuits import bristol
from repro.core.circuits.builder import CircuitBuilder
from repro.core.netlist import Netlist, OP_AND, OP_INV, OP_XOR

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures")


def _net(gates, num_wires, g_in=(), e_in=(), outputs=(), const_bits=None,
         name="t"):
    """Hand-build a raw Netlist from (op, in0, in1, out) tuples, bypassing
    the builder's folding so adversarial structures survive."""
    op = np.asarray([g[0] for g in gates], np.uint8)
    return Netlist(
        num_wires=num_wires,
        op=op,
        in0=np.asarray([g[1] for g in gates], np.int32),
        in1=np.asarray([g[2] for g in gates], np.int32),
        out=np.asarray([g[3] for g in gates], np.int32),
        garbler_inputs=np.asarray(list(g_in), np.int32),
        evaluator_inputs=np.asarray(list(e_in), np.int32),
        outputs=np.asarray(list(outputs), np.int32),
        const_bits=dict(const_bits or {}),
        name=name,
    )


# ---------------------------------------------------------------------------
# structural verifier: adversarial netlists
# ---------------------------------------------------------------------------


def test_verifier_accepts_all_generators():
    for name, build in generator_registry().items():
        errs = verify_netlist(build())
        assert errs == [], f"{name}: {errs}"


def test_verifier_cycle():
    # gate 0 reads wire 3 which gate 1 drives later: not topological
    net = _net([(OP_AND, 0, 3, 2), (OP_AND, 2, 1, 3)],
               num_wires=4, g_in=[0], e_in=[1], outputs=[3])
    errs = verify_netlist(net)
    assert any("not topological" in e for e in errs)


def test_verifier_dangling_wire():
    net = _net([(OP_XOR, 0, 5, 2)],
               num_wires=6, g_in=[0], e_in=[1], outputs=[2])
    errs = verify_netlist(net)
    assert any("dangling wire 5" in e for e in errs)


def test_verifier_conflicting_const_bits():
    # const wire driven by a gate AND const wire doubling as a party input
    net = _net([(OP_XOR, 0, 1, 2)],
               num_wires=3, g_in=[0], e_in=[1], outputs=[2],
               const_bits={2: 1, 0: 0})
    errs = verify_netlist(net)
    assert any("const wire 2 is driven" in e for e in errs)
    assert any("const wire 0 is also a party input" in e for e in errs)
    bad_bit = _net([(OP_XOR, 0, 1, 3)], num_wires=4, g_in=[0], e_in=[1],
                   outputs=[3], const_bits={2: 7})
    assert any("not 0/1" in e for e in verify_netlist(bad_bit))


def test_verifier_unreachable_output():
    # wire 4 is computed from constants only and NOT declared const
    net = _net([(OP_XOR, 0, 1, 3), (OP_AND, 2, 2, 4)],
               num_wires=5, g_in=[0], e_in=[1], outputs=[3, 4],
               const_bits={2: 1})
    errs = verify_netlist(net)
    assert any("output wire 4 is not reachable" in e for e in errs)
    # ...but a *declared* const output is legitimate (post-fold residue)
    ok = _net([(OP_XOR, 0, 1, 3)], num_wires=4, g_in=[0], e_in=[1],
              outputs=[3, 2], const_bits={2: 1})
    assert verify_netlist(ok) == []


def test_verifier_duplicate_driver_and_undriven_output():
    net = _net([(OP_XOR, 0, 1, 2), (OP_AND, 0, 1, 2)],
               num_wires=4, g_in=[0], e_in=[1], outputs=[2, 3])
    errs = verify_netlist(net)
    assert any("duplicate driver" in e for e in errs)
    assert any("output wire 3 is undriven" in e for e in errs)


def test_verifier_bad_opcode_and_inv_arity():
    bad_op = _net([(7, 0, 1, 2)], num_wires=3, g_in=[0], e_in=[1],
                  outputs=[2])
    assert any("op code 7" in e for e in verify_netlist(bad_op))
    bad_inv = _net([(OP_INV, 0, 1, 2)], num_wires=3, g_in=[0], e_in=[1],
                   outputs=[2])
    assert any("INV requires in1 == in0" in e
               for e in verify_netlist(bad_inv))


def test_verify_strict_raises_netlist_error():
    net = _net([(OP_XOR, 0, 5, 2)], num_wires=6, g_in=[0], e_in=[1],
               outputs=[2])
    with pytest.raises(NetlistError, match="dangling"):
        verify_netlist_strict(net)
    assert issubclass(NetlistError, ValueError)


# ---------------------------------------------------------------------------
# dataflow passes: golden counts on hand-built circuits
# ---------------------------------------------------------------------------


def test_dataflow_foldable_and_const():
    # AND(x, const0) folds to 0; the XOR consuming it folds to alias
    net = _net([(OP_AND, 0, 2, 3), (OP_XOR, 1, 3, 4)],
               num_wires=5, g_in=[0], e_in=[1], outputs=[4],
               const_bits={2: 0})
    rep = analyze_netlist(net)
    assert rep.foldable_gates == 2  # AND -> const0, XOR(e, 0) -> alias e
    assert rep.foldable_and == 1
    assert rep.removable_and == 1


def test_dataflow_duplicate_and():
    # two structurally identical ANDs (operand order swapped) -> one dup
    net = _net([(OP_AND, 0, 1, 2), (OP_AND, 1, 0, 3),
                (OP_XOR, 2, 3, 4)],
               num_wires=5, g_in=[0], e_in=[1], outputs=[4])
    rep = analyze_netlist(net)
    assert rep.dup_and == 1
    # ...and the XOR of two now-aliased values folds to const 0
    assert rep.foldable_gates == 1
    assert rep.removable_and == 1


def test_dataflow_inv_cancellation():
    # AND(x, INV(x)) == 0 through the negation lattice (token ^ 1)
    net = _net([(OP_INV, 0, 0, 2), (OP_AND, 0, 2, 3), (OP_XOR, 1, 3, 4)],
               num_wires=5, g_in=[0], e_in=[1], outputs=[4])
    rep = analyze_netlist(net)
    assert rep.foldable_and == 1
    assert rep.removable_and == 1


def test_dataflow_dead_gates_and_wires():
    # gate 1 output (wire 3) is never read and is not an output: dead
    net = _net([(OP_XOR, 0, 1, 2), (OP_AND, 0, 1, 3)],
               num_wires=4, g_in=[0], e_in=[1], outputs=[2])
    rep = analyze_netlist(net)
    assert rep.dead_gates == 1
    assert rep.dead_and == 1
    assert rep.dead_wires == 1
    assert rep.removable_and == 1


def test_dataflow_clean_circuit_counts_zero():
    net = _net([(OP_AND, 0, 1, 2), (OP_INV, 2, 2, 3)],
               num_wires=4, g_in=[0], e_in=[1], outputs=[3])
    rep = analyze_netlist(net)
    assert rep.summary() == {
        "dead_gates": 0, "dead_and": 0, "foldable_and": 0,
        "dup_and": 0, "removable_and": 0, "dead_wires": 0,
    }


def test_dataflow_histograms():
    cb = CircuitBuilder("h")
    a = cb.g_input_word(8)
    b = cb.e_input_word(8)
    from repro.core.circuits import arith
    cb.output(arith.add(cb, a, b))
    net = cb.build()
    rep = analyze_netlist(net, histograms=True)
    assert rep.and_per_level.sum() == rep.and_gates
    assert len(rep.live_per_level) == len(net.levels())
    assert rep.live_per_level.max() > 0


def test_stats_include_dataflow_counters():
    net = generator_registry()["gelu"]()
    st = net.stats()
    for key in ("removable_and", "dead_gates", "dup_and", "dead_wires"):
        assert key in st
    # satellite 1: the shipped generators are clean after builder CSE/prune
    assert st["removable_and"] == 0
    assert st["dead_gates"] == 0


def test_netcheck_pass_clean_on_shipped_generators():
    assert run_netcheck() == []


# ---------------------------------------------------------------------------
# builder CSE + prune (the fixes the analyzer demanded)
# ---------------------------------------------------------------------------


def test_builder_cse_dedups_and():
    cb = CircuitBuilder("cse")
    a, b = cb.g_input(), cb.e_input()
    w1 = cb.AND(a, b)
    w2 = cb.AND(b, a)  # commuted duplicate
    assert w1 == w2
    assert cb.XOR(a, cb.INV(a)) == cb.constant(1)
    assert cb.AND(a, cb.INV(a)) == cb.constant(0)


def test_builder_prune_drops_dead_cone_preserving_semantics():
    def build(prune):
        cb = CircuitBuilder("p")
        a = cb.g_input_word(8)
        b = cb.e_input_word(8)
        from repro.core.circuits import arith
        s = arith.add(cb, a, b)
        arith.mul(cb, a, b, style="conventional")  # dead cone
        cb.output(s)
        return cb.build(prune=prune)

    pruned, full = build(True), build(False)
    assert pruned.num_gates < full.num_gates
    assert analyze_netlist(pruned).dead_gates == 0
    rng = np.random.default_rng(7)
    for _ in range(4):
        ga = rng.integers(0, 2, 8).astype(np.uint8)
        eb = rng.integers(0, 2, 8).astype(np.uint8)
        assert np.array_equal(pruned.eval_plain(ga, eb),
                              full.eval_plain(ga, eb))


# ---------------------------------------------------------------------------
# bristol import hardening
# ---------------------------------------------------------------------------


def test_bristol_roundtrip_verifies():
    net = generator_registry()["add16"]()
    back = bristol.parse(bristol.emit(net), name="rt")
    rng = np.random.default_rng(3)
    ga = rng.integers(0, 2, len(net.garbler_inputs)).astype(np.uint8)
    eb = rng.integers(0, 2, len(net.evaluator_inputs)).astype(np.uint8)
    assert np.array_equal(net.eval_plain(ga, eb), back.eval_plain(ga, eb))


@pytest.mark.parametrize("text, match", [
    ("1 3\n2 1 1\n1 1\n\n2 1 0 1 2 NAND\n", "unsupported gate"),
    ("2 3\n2 1 1\n1 1\n\n2 1 0 1 2 AND\n", "promises 2 gates"),
    ("1 3\n2 1 1\n1 1\n\n2 1 0 9 2 AND\n", "out of range"),
    ("1 3\n2 1 1\n1 1\n\n1 1 0 1 2 AND\n", "AND gate must read"),
    ("1 3\n2 1 1\n1 1\n\n2 1 0 x 2 AND\n", "non-integer"),
    ("1 x\n2 1 1\n1 1\n\n2 1 0 1 2 AND\n", "non-integer"),
    ("1 3\n2 1\n1 1\n\n2 1 0 1 2 AND\n", "input header"),
    ("", ">= 3 header lines"),
])
def test_bristol_malformed_raises_value_error(text, match):
    with pytest.raises(ValueError, match=match):
        bristol.parse(text, name="bad")


def test_bristol_structural_check_catches_nontopological():
    # header/arity fine, but the gate list reads a wire driven later
    text = "2 5\n2 1 1\n1 1\n\n2 1 0 3 4 AND\n2 1 0 1 3 XOR\n"
    with pytest.raises(ValueError, match="not topological"):
        bristol.parse(text, name="cyc")
    # verify=False must let the same text through for adversarial callers
    net = bristol.parse(text, name="cyc", verify=False)
    assert net.num_gates == 2


# ---------------------------------------------------------------------------
# secret-flow linter
# ---------------------------------------------------------------------------


def test_secretflow_catches_seeded_leaks():
    path = os.path.join(FIXTURES, "leaky_party.py")
    findings = sf_lint_file(path, rel="tests/fixtures/leaky_party.py")
    rules = {(f.rule, f.symbol.rsplit(".", 1)[-1]) for f in findings}
    assert ("secret-to-wire", "leak_delta_to_wire") in rules
    assert ("secret-to-wire", "leak_mask_via_arith") in rules
    assert ("secret-to-log", "leak_zero_labels_to_log") in rules
    assert ("secret-to-exception", "leak_param_in_exception") in rules
    assert ("exc-to-wire", "leak_traceback_to_peer") in rules
    # every finding carries a usable location
    assert all(f.line > 0 and f.path.endswith("leaky_party.py")
               for f in findings)
    # the deliberately-clean methods stay quiet
    flagged = {f.symbol.rsplit(".", 1)[-1] for f in findings}
    assert "send_tables_ok" not in flagged
    assert "send_shared_ok" not in flagged


def test_secretflow_splits_seed_classes():
    """Wire-v2 seed rule: garbling-key seeds (expand to both labels ==
    the delta) are flagged however they're dressed up; the mask-label
    stream seed (expands to active labels only) is transmittable."""
    path = os.path.join(FIXTURES, "leaky_seeds.py")
    findings = sf_lint_file(path, rel="tests/fixtures/leaky_seeds.py")
    rules = {(f.rule, f.symbol.rsplit(".", 1)[-1]) for f in findings}
    assert ("secret-to-wire", "leak_garbling_key") in rules
    assert ("secret-to-wire", "leak_root_key") in rules
    assert ("secret-to-wire", "leak_key_attr") in rules
    assert ("secret-to-wire", "leak_key_as_seed_stream") in rules
    flagged = {f.symbol.rsplit(".", 1)[-1] for f in findings}
    assert "send_mask_stream_seed_ok" not in flagged


def test_secretflow_flags_span_attribute_leaks():
    """A span recording label/mask/delta bytes is flagged
    (``secret-to-span``); the shipped size/tag/count attributes are
    not."""
    path = os.path.join(FIXTURES, "leaky_spans.py")
    findings = sf_lint_file(path, rel="tests/fixtures/leaky_spans.py")
    rules = {(f.rule, f.symbol.rsplit(".", 1)[-1]) for f in findings}
    assert ("secret-to-span", "leak_labels_to_span") in rules
    assert ("secret-to-span", "leak_delta_to_instant") in rules
    assert ("secret-to-span", "leak_mask_via_arith_to_timer") in rules
    flagged = {f.symbol.rsplit(".", 1)[-1] for f in findings}
    assert "span_sizes_ok" not in flagged
    assert "span_counts_ok" not in flagged


def test_secretflow_flags_retry_path_leaks():
    """Resilience/retry error paths: ``str(e)``/tracebacks to the wire
    and label bytes in burn instants are flagged; the shipped
    class-name-only idiom stays quiet."""
    path = os.path.join(FIXTURES, "leaky_retry.py")
    findings = sf_lint_file(path, rel="tests/fixtures/leaky_retry.py")
    rules = {(f.rule, f.symbol.rsplit(".", 1)[-1]) for f in findings}
    assert ("exc-to-wire", "leak_exc_text_on_retry") in rules
    assert ("exc-to-wire", "leak_traceback_on_lease_drop") in rules
    assert ("secret-to-span", "leak_labels_in_burn_instant") in rules
    flagged = {f.symbol.rsplit(".", 1)[-1] for f in findings}
    assert "retry_classname_ok" not in flagged
    assert "burn_instant_ok" not in flagged


def test_secretflow_quiet_on_shipped_protocol_paths():
    # DEFAULT_PATHS includes the fault-injection + resilience modules:
    # their retry/burn/error paths must stay class-name-only, with zero
    # baseline entries
    from repro.analysis.secretflow import DEFAULT_PATHS

    assert "src/repro/net/resilience.py" in DEFAULT_PATHS
    assert "src/repro/net/faults.py" in DEFAULT_PATHS
    assert run_secretflow(REPO) == []


# ---------------------------------------------------------------------------
# jit-hygiene linter
# ---------------------------------------------------------------------------


def test_jit_hygiene_catches_seeded_violations():
    path = os.path.join(FIXTURES, "bad_jit.py")
    findings = run_jit_hygiene(REPO, jit_paths=[path], proto_paths=[path])
    rules = {f.rule for f in findings}
    assert {"jit-py-branch", "jit-host-np", "jit-host-cast",
            "jit-time-random", "proto-global-rng"} <= rules
    symbols = {f.symbol.rsplit(".", 1)[-1] for f in findings}
    assert "clean" not in symbols


def test_jit_hygiene_quiet_on_shipped_kernels():
    assert run_jit_hygiene(REPO) == []


# ---------------------------------------------------------------------------
# baseline ratchet + CLI
# ---------------------------------------------------------------------------


def _leaky_findings():
    return sf_lint_file(os.path.join(FIXTURES, "leaky_party.py"),
                        rel="tests/fixtures/leaky_party.py")


def test_baseline_accepts_and_ratchets(tmp_path):
    findings = _leaky_findings()
    doc = Baseline.from_findings(findings, reason="fixture")
    p = tmp_path / "b.json"
    p.write_text(json.dumps(doc))
    base = Baseline.load(str(p))
    assert all(base.accepts(f) for f in findings)
    # growth past the baselined count is NOT accepted
    f = findings[0]
    grown = Finding(f.tool, f.rule, f.path, f.line, f.symbol, f.message,
                    count=f.count + 1)
    assert not base.accepts(grown)
    # entries without an explicit reason are rejected at load time
    del doc["findings"][0]["reason"]
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="reason"):
        Baseline.load(str(p))


def test_cli_exit_codes(tmp_path, capsys):
    leaky = os.path.join(FIXTURES, "leaky_party.py")
    # clean tree passes
    assert lint_cli.main(["--netlists", "--root", REPO]) == 0
    capsys.readouterr()
    # seeded violations fail with file:line renderings
    rc = lint_cli.main(["--secretflow", "--root", REPO, leaky])
    out = capsys.readouterr().out
    assert rc == 1
    assert "leaky_party.py:" in out and "secret-to-wire" in out
    # --json emits machine-readable findings
    rc = lint_cli.main(["--secretflow", "--json", "--root", REPO, leaky])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert doc["new"] and all("rule" in f for f in doc["findings"])
    # a baseline accepting those findings flips the exit back to 0
    bpath = tmp_path / "base.json"
    rc = lint_cli.main(["--secretflow", "--root", REPO, "--baseline",
                        str(bpath), "--update-baseline", leaky])
    assert rc == 0
    capsys.readouterr()
    rc = lint_cli.main(["--secretflow", "--root", REPO, "--baseline",
                        str(bpath), leaky])
    assert rc == 0
    capsys.readouterr()
    # missing baseline file is a hard error, not a silent pass
    assert lint_cli.main(["--secretflow", "--root", REPO, "--baseline",
                          str(tmp_path / "absent.json"), leaky]) == 2
    capsys.readouterr()


def test_cli_module_entrypoint():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--netlists",
         "--root", REPO],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_checked_in_baseline_matches_clean_tree():
    # the CI contract: the shipped tree with the shipped baseline is green
    base = Baseline.load(os.path.join(REPO, "analysis", "baseline.json"))
    assert base.entries == {}  # nothing grandfathered on the shipped tree
    assert lint_cli.main(
        ["--secretflow", "--jit", "--root", REPO, "--baseline",
         "analysis/baseline.json"]) == 0

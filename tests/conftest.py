import os
import sys

# single-device tests: dryrun.py sets its own XLA_FLAGS in a subprocess;
# everything here sees 1 CPU device.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

# the privacy plane (HE shares mod ~2^40, uint64 NTT lanes) needs x64;
# model code is dtype-explicit so enabling it globally is safe.
jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)

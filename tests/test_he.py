"""BFV-lite: exactness of enc/dec and the homomorphic surface."""

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # offline CI: deterministic fallback shim
    from _hyp_fallback import given, settings, strategies as st

from repro.core import he as HE


@pytest.fixture(scope="module")
def ctx():
    p = HE.make_params(n=256, log_q=30, num_primes=3, t_bits=26)
    s, pk = HE.keygen(p, jax.random.PRNGKey(0))
    return p, s, pk


def test_slot_roundtrip(ctx, rng):
    p, s, pk = ctx
    v = rng.integers(0, p.t, p.n)
    ct = HE.encrypt(p, pk, HE.encode_slots(p, v), jax.random.PRNGKey(1))
    dec = HE.decode_slots(p, HE.decrypt(p, s, ct))
    assert np.array_equal(dec, v % p.t)


def test_homomorphic_add(ctx, rng):
    p, s, pk = ctx
    v1 = rng.integers(0, p.t, p.n)
    v2 = rng.integers(0, p.t, p.n)
    ct1 = HE.encrypt(p, pk, HE.encode_slots(p, v1), jax.random.PRNGKey(2))
    ct2 = HE.encrypt(p, pk, HE.encode_slots(p, v2), jax.random.PRNGKey(3))
    dec = HE.decode_slots(p, HE.decrypt(p, s, HE.add_ct(p, ct1, ct2)))
    assert np.array_equal(dec, (v1 + v2) % p.t)


def test_slotwise_plain_mult(ctx, rng):
    p, s, pk = ctx
    v = rng.integers(0, p.t, p.n)
    w = rng.integers(0, 1 << 12, p.n)  # bounded plaintext magnitude
    ct = HE.encrypt(p, pk, HE.encode_slots(p, v), jax.random.PRNGKey(4))
    ctw = HE.mul_plain(p, ct, HE.encode_slots(p, w))
    dec = HE.decode_slots(p, HE.decrypt(p, s, ctw))
    assert np.array_equal(
        dec.astype(object), (v.astype(object) * w.astype(object)) % p.t
    )


def test_add_plain(ctx, rng):
    p, s, pk = ctx
    v = rng.integers(0, p.t, p.n)
    w = rng.integers(0, p.t, p.n)
    ct = HE.encrypt(p, pk, HE.encode_slots(p, v), jax.random.PRNGKey(5))
    ct2 = HE.add_plain(p, ct, HE.encode_slots(p, w))
    dec = HE.decode_slots(p, HE.decrypt(p, s, ct2))
    assert np.array_equal(dec, (v + w) % p.t)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_matvec_property(ctx, seed):
    p, s, pk = ctx
    rng = np.random.default_rng(seed)
    d_in, d_out = 16, 11
    r = rng.integers(0, p.t, d_in)
    W = rng.integers(-100, 100, (d_out, d_in))
    ctr = HE.encrypt(p, pk, HE.encode_coeffs(p, r), jax.random.PRNGKey(seed))
    outs = HE.he_matvec(p, ctr, W)
    polys = [HE.decrypt(p, s, c) for c in outs]
    got = HE.he_matvec_extract(p, polys, d_in, d_out)
    want = (W.astype(object) @ r.astype(object)) % p.t
    assert np.array_equal(got.astype(object), want)


def test_signed_centering_keeps_noise_small(ctx, rng):
    """Negative plaintexts (residues near t) must not blow up noise."""
    p, s, pk = ctx
    v = rng.integers(0, p.t, p.n)
    w_signed = rng.integers(-2000, 2000, p.n)
    ct = HE.encrypt(p, pk, HE.encode_slots(p, v), jax.random.PRNGKey(7))
    ctw = HE.mul_plain(p, ct, HE.encode_slots(p, np.mod(w_signed, p.t)))
    dec = HE.decode_slots(p, HE.decrypt(p, s, ctw))
    want = (v.astype(object) * np.mod(w_signed, p.t).astype(object)) % p.t
    assert np.array_equal(dec.astype(object), want)


def test_params_validity():
    p = HE.make_params(n=256, num_primes=3, t_bits=30)
    for q in p.qs:
        assert q % (2 * p.n) == 1
    assert p.t % (2 * p.n) == 1
    assert p.t not in p.qs

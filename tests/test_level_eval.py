"""Fused level-evaluator kernel: oracle equality + end-to-end semantic
correctness against a full garbled circuit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.circuits import arith
from repro.core.circuits.builder import CircuitBuilder
from repro.core.garble import garble, encode_inputs, const_labels, decode_outputs
from repro.core.netlist import OP_INV
from repro.kernels.halfgate import ref as HG
from repro.kernels.level_eval import ref as LE
from repro.kernels.level_eval.level_eval import eval_level_pallas


@pytest.mark.parametrize("g", [5, 128, 3000])
def test_fused_matches_oracle(g):
    ks = jax.random.split(jax.random.PRNGKey(g), 5)
    a = jax.random.bits(ks[0], (g, 4), dtype=jnp.uint32)
    b = jax.random.bits(ks[1], (g, 4), dtype=jnp.uint32)
    tg = jax.random.bits(ks[2], (g, 4), dtype=jnp.uint32)
    te = jax.random.bits(ks[3], (g, 4), dtype=jnp.uint32)
    ops = jax.random.randint(ks[4], (g,), 0, 3).astype(jnp.uint32)
    tw = jnp.arange(g, dtype=jnp.uint32)
    want = LE.eval_level(ops, a, b, tg, te, tw)
    got = eval_level_pallas(ops, a, b, tg, te, tw, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_fused_level_evaluates_circuit(rng):
    """Walk a real garbled adder level-by-level with the fused kernel and
    decode the correct sum."""
    k = 8
    cb = CircuitBuilder()
    wa = cb.g_input_word(k)
    wb = cb.e_input_word(k)
    cb.output(arith.add(cb, wa, wb))
    net = cb.build()
    I = 1
    gc = garble(net, jax.random.PRNGKey(3), I, impl="ref")
    av, bv = int(rng.integers(0, 256)), int(rng.integers(0, 256))
    bits = lambda v: np.array([[(v >> i) & 1 for i in range(k)]])
    active = {}
    lab = encode_inputs(gc, net.garbler_inputs, bits(av))
    for j, w in enumerate(net.garbler_inputs):
        active[int(w)] = lab[:, j]
    lab = encode_inputs(gc, net.evaluator_inputs, bits(bv))
    for j, w in enumerate(net.evaluator_inputs):
        active[int(w)] = lab[:, j]
    active.update(const_labels(gc))

    wires = np.zeros((net.num_wires, 4), np.uint32)
    for w, v in active.items():
        wires[int(w)] = np.asarray(v)[0]
    and_idx = net.and_gate_index()
    tables = np.asarray(gc.tables)[0]
    for lvl in net.levels():
        ops = jnp.asarray(net.op[lvl], jnp.uint32)
        a = jnp.asarray(wires[net.in0[lvl]])
        b = jnp.asarray(wires[net.in1[lvl]])
        slots = np.where(net.op[lvl] == 1, and_idx[lvl], 0)
        tg = jnp.asarray(tables[slots, 0])
        te = jnp.asarray(tables[slots, 1])
        tw = jnp.asarray(slots, jnp.uint32)
        out = eval_level_pallas(ops, a, b, tg, te, tw, interpret=True)
        wires[net.out[lvl]] = np.asarray(out)
    out_lab = jnp.asarray(wires[net.outputs])[None]
    got_bits = decode_outputs(gc, out_lab)[0]
    got = sum(int(x) << i for i, x in enumerate(got_bits))
    assert got == (av + bv) % (1 << k)

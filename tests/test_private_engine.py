"""PrivateServeEngine under concurrency: bundle-pool races between
``serve``, ``refill_async`` and ``maintain``, and the ``BundlePoolEmpty``
load-shedding path."""

import threading

import numpy as np
import pytest

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.serve import BundlePoolEmpty, PrivateRequest, PrivateServeEngine

D, HEADS, DFF, S = 8, 2, 16, 4


def _model(seed=0):
    rng = np.random.default_rng(seed)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    return PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=seed)


@pytest.fixture(scope="module")
def engine_model():
    return _model()


def _request(rng):
    return PrivateRequest(x=rng.normal(0, 1, (S, D)))


def test_serve_while_refill_in_flight(engine_model):
    """A serve racing a background refill: both finish, the result is
    correct, and the pool ends at exactly preprocessed − consumed."""
    engine = PrivateServeEngine(engine_model, buckets=(S,), pool_target=3,
                                impl="ref")
    engine.preprocess(S, 1)
    rng = np.random.default_rng(1)
    th = engine.refill_async(S, 2)  # explicit count: +2 whatever the order
    req = _request(rng)
    engine.serve([req])  # may run before, during or after the refill
    th.join(timeout=600)
    want = engine_model.forward_float(req.x)
    assert np.abs(req.result - want).max() < 0.25
    assert engine.pool_size(S) == 2  # 1 + 2 refilled − 1 consumed


def test_concurrent_serves_race_one_bundle(engine_model):
    """Two serves, one bundle: exactly one wins, the loser sheds load
    with BundlePoolEmpty — never a crash, never a double-consume."""
    engine = PrivateServeEngine(engine_model, buckets=(S,), pool_target=1,
                                impl="ref")
    engine.preprocess(S, 1)
    rng = np.random.default_rng(2)
    results, errors = [], []
    barrier = threading.Barrier(2)

    def worker():
        barrier.wait()
        try:
            r = _request(rng)
            engine.serve([r])
            results.append(r)
        except BundlePoolEmpty as e:
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=600)
    assert len(results) == 1 and len(errors) == 1
    assert results[0].result is not None
    assert engine.pool_size(S) == 0


def test_concurrent_maintain_does_not_overshoot(engine_model):
    """Racing maintains compute the deficit under the bucket lock: the
    pool converges to pool_target, not N × pool_target."""
    engine = PrivateServeEngine(engine_model, buckets=(S,), pool_target=2,
                                impl="ref")
    threads = [threading.Thread(target=engine.maintain, args=(S,))
               for _ in range(3)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=600)
    assert engine.pool_size(S) == 2


def test_auto_refill_serves_from_empty_pool(engine_model):
    engine = PrivateServeEngine(engine_model, buckets=(S,), pool_target=0,
                                auto_refill=True, impl="ref")
    rng = np.random.default_rng(3)
    req = _request(rng)
    engine.serve([req])  # preprocesses one bundle on demand
    assert req.result is not None
    assert engine.pool_size(S) == 0


def test_shed_carries_retry_after_hint(engine_model):
    """A dry-pool shed carries a retry-after hint computed from observed
    preprocessing time × refill queue depth — None only before any
    preprocessing has ever been timed."""
    engine = PrivateServeEngine(engine_model, buckets=(S,), pool_target=1,
                                impl="ref")
    rng = np.random.default_rng(5)
    with pytest.raises(BundlePoolEmpty) as ei:
        engine.serve([_request(rng)])  # nothing observed yet: no guess
    assert ei.value.retry_after_s is None

    engine.preprocess(S, 1)  # the EWMA now has a real data point
    engine.serve([_request(rng)])  # drains the pool
    with pytest.raises(BundlePoolEmpty) as ei:
        engine.serve([_request(rng)])
    assert ei.value.retry_after_s is not None
    assert ei.value.retry_after_s > 0
    assert ei.value.scope == "pool"


def test_failed_serve_returns_fresh_bundle_to_pool(engine_model):
    """A bad request must not burn the (expensive) bundle it claimed."""
    engine = PrivateServeEngine(engine_model, buckets=(S,), pool_target=1,
                                impl="ref")
    engine.preprocess(S, 1)
    rng = np.random.default_rng(4)
    bad = PrivateRequest(x=rng.normal(0, 1, (S, D + 1)))  # wrong width
    with pytest.raises(ValueError):
        engine.serve([bad])
    assert engine.pool_size(S) == 1  # bundle back in the pool
    good = _request(rng)
    engine.serve([good])
    assert good.result is not None

"""Roofline machinery: collective parser, trip-count-corrected HLO costs."""

import numpy as np

from repro.config import get_config
from repro.config.base import SHAPES_BY_NAME
from repro.roofline.analysis import (
    HW, model_flops, parse_collectives, roofline_terms,
)
from repro.roofline.hlo_costs import analyze_hlo

FAKE_HLO = """
HloModule jit_f

%region_0.2 (arg.1: (s32[], f32[256,256])) -> (s32[], f32[256,256]) {
  %p = f32[256,256]{1,0} parameter(0)
  %d = f32[256,256]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[256,512]{1,0} all-gather(%d), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = f32[256,256]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256]
}

%region_1.3 (arg: s32[]) -> pred[] {
  %c = s32[] constant(8)
}

ENTRY %main.5 (a: f32[256,256]) -> f32[256,256] {
  %a = f32[256,256]{1,0} parameter(0)
  %w = (s32[], f32[256,256]) while(%a), condition=%region_1.3, body=%region_0.2, backend_config={"known_trip_count":{"n":"8"}}
  %rs = f32[16,256]{1,0} reduce-scatter(%a), replica_groups=[16,16]<=[256]
}
"""


def test_parse_collectives_kinds():
    out = parse_collectives(FAKE_HLO)
    kinds = out["per_kind"]
    assert kinds["all-gather"]["count"] == 1
    assert kinds["all-reduce"]["count"] == 1
    assert kinds["reduce-scatter"]["count"] == 1
    # all-gather wire bytes = result bytes = 256*512*4
    assert kinds["all-gather"]["wire_bytes"] == 256 * 512 * 4


def test_trip_count_multiplier():
    out = analyze_hlo(FAKE_HLO)
    # dot inside the 8-trip while: 2*256*256*256*8 flops
    assert out["dot_flops"] == 2 * 256 * 256 * 256 * 8
    coll = out["collectives"]["per_kind"]
    assert coll["all-gather"]["count"] == 8  # multiplied
    assert coll["reduce-scatter"]["count"] == 1  # entry-level
    # all-reduce wire = 2x operand (resolved through the symbol table)
    assert coll["all-reduce"]["wire_bytes"] == 8 * 2 * 256 * 256 * 4


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 0.0, 0.0)  # 1s of pure compute
    assert t["dominant"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(0.0, 819e9, 1e9)
    assert t["dominant"] == "memory"


def test_model_flops_scaling():
    cfg = get_config("llama3.2-1b")
    tr = model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    pf = model_flops(cfg, SHAPES_BY_NAME["prefill_32k"])
    de = model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    assert tr > pf > de > 0
    # train = 6·N·D vs prefill 2·N·D with equal token counts
    n_tr = 256 * 4096
    n_pf = 32 * 32768
    assert abs((tr / n_tr) / (pf / n_pf) - 3.0) < 1e-6


def test_moe_active_params():
    from repro.roofline.analysis import active_params

    cfg = get_config("olmoe-1b-7b")
    act = active_params(cfg)
    tot = cfg.num_params()
    assert act < tot * 0.35  # 8/64 experts active + dense parts

"""The compile → preprocess → run lifecycle: phase separation, bundle
pooling, parity with the legacy eager path, and pool exhaustion."""

import numpy as np
import pytest

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.core.plan import GC_KINDS, compile_plan
from repro.core.session import BundleExhausted, compile
from repro.serve import BundlePoolEmpty, PrivateRequest, PrivateServeEngine

D, HEADS, DFF, S = 8, 2, 16, 4


def _model(seed=0, frac=6):
    rng = np.random.default_rng(seed)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=frac)
    return PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=seed)


@pytest.fixture(scope="module")
def served():
    """One shared transcript: legacy forward + preprocess(2) + 2 runs."""
    model = _model()
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (S, D))
    y_legacy = model.forward_private(x)

    sess = model.compile_session(S)
    bundles = sess.preprocess(2)
    snap_pre = sess.stats.comm_snapshot()
    y1 = sess.run(x, bundles[0])
    snap_run1 = sess.stats.comm_snapshot()
    y2 = sess.run(x, bundles[1])
    snap_run2 = sess.stats.comm_snapshot()
    return dict(model=model, sess=sess, bundles=bundles, x=x,
                y_legacy=y_legacy, y1=y1, y2=y2,
                snaps=(snap_pre, snap_run1, snap_run2))


def test_plan_traces_forward_private():
    plan = compile_plan(_model(), S)
    kinds = {op.kind for op in plan.ops}
    assert kinds == {"linear", "beaver_matmul", "gc_apply", "layernorm",
                     "trunc"}
    per_layer = 14 + 4 * HEADS  # qkv(6) + 4/head + wo(2) + mlp(4) + 2 LN
    assert len(plan.ops) == per_layer * plan.n_layers
    names = [op.name for op in plan.ops]
    assert len(set(names)) == len(names)  # bundle part keys are unique
    # shapes/scales resolved for the bucket
    sm = next(op for op in plan.ops if op.attrs.get("circuit") == "softmax")
    assert sm.shape == (S, S) and sm.in_scale == 2 * plan.frac
    # scheduling hook: every GC unit op lands on some core exactly once
    cores = plan.coarse_schedule(4)
    flat = [nm for core in cores for nm in core]
    assert sorted(flat) == sorted(op.name for op in plan.gc_ops())


def test_session_matches_legacy_and_float(served):
    # the session replays the same protocol transcript → identical output
    assert np.array_equal(served["y1"], served["y_legacy"])
    assert np.array_equal(served["y2"], served["y1"])
    # and both track the float reference
    want = served["model"].forward_float(served["x"])
    assert np.abs(served["y1"] - want).max() < 0.25


def test_phase_split_traffic(served):
    snap_pre, snap_run1, snap_run2 = served["snaps"]
    # all garbling/HE/triple traffic metered offline before the first run
    assert snap_pre["offline"]["total"] > 0
    assert any(k.startswith("tables") for k in snap_pre["offline"]["by_tag"])
    assert "beaver" in snap_pre["offline"]["by_tag"]
    assert "he-enc-r" in snap_pre["offline"]["by_tag"]
    # runs add ZERO offline traffic…
    assert snap_run1["offline"] == snap_pre["offline"]
    assert snap_run2["offline"] == snap_pre["offline"]
    # …and byte-identical online traffic per run, tag by tag
    d1 = {k: snap_run1["online"]["by_tag"][k] -
          snap_pre["online"]["by_tag"].get(k, 0)
          for k in snap_run1["online"]["by_tag"]}
    d2 = {k: snap_run2["online"]["by_tag"][k] -
          snap_run1["online"]["by_tag"].get(k, 0)
          for k in snap_run2["online"]["by_tag"]}
    assert d1 == d2
    assert any(k.startswith("ot") for k in d1)


def test_batched_garbling_one_call_per_netlist(served):
    """Preprocess garbles each distinct netlist once for the whole batch."""
    sess = served["sess"]
    plan = sess.plan
    per_net = {}
    for op in plan.ops:
        if op.kind in GC_KINDS:
            net = sess._gc_net(op)
            per_net[net.name] = per_net.get(net.name, 0) + plan.gc_instances(op)
    st = sess.stats
    for name, per_req in per_net.items():
        # 2 bundles preprocessed + nothing extra during the runs
        assert st.per_fn[name]["instances"] == 2 * per_req


def test_run_raises_on_consumed_bundle(served):
    sess = served["sess"]
    with pytest.raises(BundleExhausted):
        sess.run(served["x"], served["bundles"][0])


def test_run_rejects_foreign_bundles(served):
    # different bucket shape
    other = served["model"].compile_session(S + 1)
    with pytest.raises(BundleExhausted):
        served["sess"].run(served["x"], other.preprocess(1)[0])
    # same shape but a different session: structurally identical plan,
    # different garbled circuits/weights — must not be silently accepted
    twin = served["model"].compile_session(S, seed=99)
    with pytest.raises(BundleExhausted):
        served["sess"].run(served["x"], twin.preprocess(1)[0])


def test_private_engine_pool_and_exhaustion():
    model = _model(seed=2)
    rng = np.random.default_rng(3)
    engine = PrivateServeEngine(model, buckets=(S,), pool_target=2)
    assert engine.preprocess(S, 2) == 2
    reqs = [PrivateRequest(x=rng.normal(0, 1, (S, D))) for _ in range(2)]
    engine.serve(reqs)
    want0 = model.forward_float(reqs[0].x)
    assert np.abs(reqs[0].result - want0).max() < 0.25
    assert reqs[1].result is not None
    assert engine.pool_size(S) == 0
    # pool dry + no auto refill → clean failure for load shedding
    with pytest.raises(BundlePoolEmpty):
        engine.serve([PrivateRequest(x=rng.normal(0, 1, (S, D)))])
    # background refill path tops the pool back up
    engine.refill_async(S, 1).join(timeout=600)
    assert engine.pool_size(S) == 1
    st = engine.stats(S)
    assert st.offline.channel.total > 0 and st.online.channel.total > 0

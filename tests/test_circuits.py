"""Nonlinear-circuit numerics vs float oracles + paper AND-count claims."""

import math

import numpy as np
import pytest

from repro.core.circuits import nonlinear as NL
from repro.core.circuits.builder import CircuitBuilder

K, FRAC = 24, 10


def to_bits(vals, k=K):
    vals = np.asarray(np.round(vals), np.int64) % (1 << k)
    return ((vals[:, None] >> np.arange(k)) & 1).astype(np.uint8)


def from_bits(bits, k=K):
    v = (bits.astype(np.int64) << np.arange(k)).sum(-1)
    return np.where(v >= (1 << (k - 1)), v - (1 << k), v)


def test_exp_circuit():
    cb = CircuitBuilder()
    x = cb.e_input_word(K)
    cb.output(NL.exp_circuit(cb, x, FRAC, "xfbq"))
    net = cb.build()
    xs = np.array([-0.1, -0.5, -1.0, -2.5, -4.0, -8.0, 0.0, -0.03, -20.0])
    out = net.eval_plain(np.zeros((len(xs), 0)), to_bits(xs * (1 << FRAC)))
    got = from_bits(out.reshape(len(xs), K)) / (1 << FRAC)
    assert np.abs(got - np.exp(xs)).max() < 0.01


def test_reciprocal_circuit():
    cb = CircuitBuilder()
    x = cb.e_input_word(K)
    cb.output(NL.reciprocal_circuit(cb, x, FRAC, "xfbq"))
    net = cb.build()
    xs = np.array([1.0, 2.0, 0.5, 3.7, 10.0, 0.13, 77.0, 1.99])
    out = net.eval_plain(np.zeros((len(xs), 0)), to_bits(xs * (1 << FRAC)))
    got = from_bits(out.reshape(len(xs), K)) / (1 << FRAC)
    assert np.abs(got * xs - 1).max() < 0.05


def test_rsqrt_circuit():
    cb = CircuitBuilder()
    x = cb.e_input_word(K)
    cb.output(NL.rsqrt_circuit(cb, x, FRAC, "xfbq"))
    net = cb.build()
    xs = np.array([1.0, 2.0, 4.0, 0.25, 9.0, 16.4, 0.9, 3.99, 255.0])
    out = net.eval_plain(np.zeros((len(xs), 0)), to_bits(xs * (1 << FRAC)))
    got = from_bits(out.reshape(len(xs), K)) / (1 << FRAC)
    assert np.abs(got * np.sqrt(xs) - 1).max() < 0.02


@pytest.mark.parametrize("style", ["xfbq", "conventional"])
def test_softmax_circuit(style, rng):
    net = NL.softmax_circuit(8, k=K, frac=FRAC, style=style).build()
    rows = rng.normal(0, 2, (4, 8))
    fx = np.round(rows * (1 << FRAC)).astype(np.int64)
    bits = np.concatenate([to_bits(fx[:, i]) for i in range(8)], axis=1)
    out = net.eval_plain(np.zeros((4, 0)), bits)
    got = from_bits(out.reshape(4, 8, K)) / (1 << FRAC)
    want = np.exp(rows - rows.max(1, keepdims=True))
    want /= want.sum(1, keepdims=True)
    assert np.abs(got - want).max() < 0.02


def test_gelu_circuit():
    net = NL.gelu_circuit(k=21, frac=10).build()
    xs = np.array([-5.0, -2.0, -0.5, 0.0, 0.7, 2.2, 4.5, 3.99, -3.9])
    out = net.eval_plain(np.zeros((len(xs), 0)), to_bits(xs * (1 << 10), 21))
    got = from_bits(out.reshape(len(xs), 21), 21) / (1 << 10)
    want = np.array([NL._gelu(max(min(v, 4), -4)) for v in xs])
    assert np.abs(got - want).max() < 0.05


def test_fig9a_and_reduction_per_function():
    """Fig. 9(a): XFBQ cuts per-function ANDs vs conventional multipliers."""
    red = {}
    for name, build in [
        ("softmax", lambda s: NL.softmax_circuit(8, k=K, frac=FRAC, style=s)),
        ("gelu", lambda s: NL.gelu_circuit(k=21, frac=10, style=s)),
        ("layernorm", lambda s: NL.layernorm_full_circuit(8, k=K, frac=FRAC,
                                                          style=s)),
    ]:
        conv = build("conventional").build().and_count
        xfbq = build("xfbq").build().and_count
        red[name] = 1 - xfbq / conv
    # paper: softmax −48.1%, gelu −33.7%, layernorm −45.6% (vs Testa);
    # bands are generous since our baseline is plain schoolbook.
    assert 0.25 < red["softmax"] < 0.65, red
    assert 0.10 < red["gelu"] < 0.60, red
    assert 0.25 < red["layernorm"] < 0.65, red


def test_layernorm_reduced_vs_full():
    """APINT Ĉ₂ drops ≥40% of the LayerNorm GC workload (paper: 47.3%)."""
    full = NL.layernorm_full_circuit(8, k=K, frac=FRAC).build().and_count
    red = NL.layernorm_reduced_circuit(8, k=K, frac=FRAC).build().and_count
    assert 0.35 < 1 - red / full < 0.65


def test_netlist_stats_and_levels():
    net = NL.gelu_circuit(k=21, frac=10).build()
    st = net.stats()
    assert st["and"] > 0 and st["xor"] > 0
    assert st["garbled_table_bytes"] == 32 * st["and"]
    levels = net.levels()
    assert sum(len(l) for l in levels) == net.num_gates
    # levels are a valid topological layering
    pos = {}
    for li, lvl in enumerate(levels):
        for g in lvl:
            pos[int(net.out[g])] = li
    for g in range(net.num_gates):
        glv = pos[int(net.out[g])]
        for w in (int(net.in0[g]), int(net.in1[g])):
            if w in pos:
                assert pos[w] < glv

"""Seed-handling fixture for the secret-flow linter (wire v2).

The v2 wire format ships PRG seeds instead of raw label streams, which
creates a new leak class the linter must split correctly: a seed that
expands to BOTH labels of a wire (the garbling key) is equivalent to
the FreeXOR delta — with it on the wire every complement label decodes
— while the mask-label stream seed expands only to ACTIVE labels the
evaluator is entitled to, so ``stream_seed``'s result is transmittable
by protocol design. This module is linted by path only and is never
imported by the package.
"""

import jax

from repro.core import labels as LB


class SeedyEndpoint:
    def __init__(self, transport, protocol, rng):
        self.transport = transport
        self.p = protocol
        self.rng = rng

    def leak_garbling_key(self):
        # the per-netlist garbling key derives both labels of every wire
        self.transport.send(bytes(self.p._next_key()))

    def leak_root_key(self, seed):
        # the session root key is every garbling key at once
        key = jax.random.PRNGKey(seed)
        self.transport.send(key.tobytes())

    def leak_key_attr(self):
        # reading the protocol's key attribute is just as fatal
        self.transport.send(self.p.key.tobytes())

    def leak_key_as_seed_stream(self):
        # dressing the garbling key up as a v2 seed-stream record must
        # NOT launder the taint (pack_seed_stream is not a sanitizer)
        from repro.net import wire as W

        rec = W.pack_seed_stream(bytes(self.p._next_key())[:16], 0, 8)
        self.transport.send(rec)

    def send_mask_stream_seed_ok(self):
        # the approved v2 path: a fresh active-label stream seed
        seed = LB.stream_seed(self.rng)
        self.transport.send(seed)

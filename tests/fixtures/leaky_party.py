"""Deliberately leaky protocol endpoint — secret-flow linter fixture.

Each method seeds exactly one violation class; ``tests/test_analysis.py``
asserts the linter reports every one of them with a file:line. This
module is linted by path only and is never imported by the package.
"""

import logging
import traceback

import numpy as np

log = logging.getLogger("leaky")


class LeakyEndpoint:
    def __init__(self, transport, gcirc, rng):
        self.transport = transport
        self.gcirc = gcirc
        self.rng = rng

    def leak_delta_to_wire(self):
        # the FreeXOR offset: with R on the wire, every label pair decodes
        self.transport.send(self.gcirc.r.tobytes())

    def leak_mask_via_arith(self, t):
        # taint must survive the arithmetic rewrite of the mask
        masks = self.rng.integers(0, t, 8, dtype=np.uint64)
        negated = (t - masks) % t
        self.transport.send(negated.tobytes())

    def leak_zero_labels_to_log(self):
        log.info("wire zeros: %r", self.gcirc.input_zero)

    def leak_param_in_exception(self, s_mask):
        # parameter named like a secret field is secret by convention
        raise RuntimeError(f"bad mask {s_mask!r}")

    def leak_traceback_to_peer(self):
        try:
            self.step()
        except Exception as e:  # noqa: BLE001 — fixture
            self.transport.send(f"error: {e}\n{traceback.format_exc()}")

    def send_tables_ok(self):
        # public projection of a secret-holding object: must NOT be flagged
        self.transport.send(self.gcirc.tables.tobytes())

    def send_shared_ok(self, enc, t):
        # approved masking API: must NOT be flagged
        from repro.core import secret_sharing as SS

        keep, send = SS.share(self.rng, enc, t)
        self.transport.send(send.tobytes())
        return keep

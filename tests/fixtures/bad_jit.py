"""Deliberately unhygienic jitted kernels — jit-hygiene linter fixture.

Each function seeds one violation; ``tests/test_analysis.py`` asserts the
linter reports all of them. Linted by path only, never imported (the AST
walk does not execute the module, so the jax import is never resolved).
"""

import time

import numpy as np

import jax
import jax.numpy as jnp


@jax.jit
def branchy(x):
    if x > 0:  # Python branch on a traced value
        return x
    return -x


def hosty(x):
    y = np.sqrt(x)  # host numpy on a traced value inside a jitted body
    return jnp.asarray(y)


hosty_jit = jax.jit(hosty)


@jax.jit
def casty(x):
    return float(x) * 2.0  # host cast forces concretization


@jax.jit
def timed(x):
    t0 = time.time()  # wall clock inside a traced body
    return x + t0


def seeded(shape):
    return np.random.rand(*shape)  # global RNG in a protocol-path module


@jax.jit
def clean(x, n, *, flavor="fast"):
    # static_argnames branch and self-free attribute reads must stay quiet
    return jnp.where(x > 0, x, -x) * n

"""Deliberately leaky retry/lease error paths — secret-flow fixture.

Resilience code sits exactly where exceptions meet the wire: backoff
loops catch transport/protocol errors and then talk to the peer (error
frames, resume hellos) and to telemetry (burn/retry instants). The rule
pinned here is class-name-only: ``type(e).__name__`` is the most an
error path may ship or record — ``str(e)``/``repr(e)``/tracebacks
interpolate live values (label bytes, mask words, key material in the
worst case). Each ``leak_*`` method seeds one violation; the ``*_ok``
methods are the shipped idiom and must stay quiet. Linted by path only,
never imported.
"""

import traceback

from repro import obs
from repro.net.transport import TransportClosed


class LeakyRetry:
    def __init__(self, transport, gcirc):
        self.transport = transport
        self.gcirc = gcirc

    def leak_exc_text_on_retry(self, frame):
        # str(e) in the error frame: whatever the exception interpolated
        # (a slab slice, a mask word) goes to the peer
        try:
            self.transport.send(frame)
        except TransportClosed as e:
            self.transport.send(f"error retrying: {e}".encode())

    def leak_traceback_on_lease_drop(self, frame):
        try:
            self.transport.send(frame)
        except TransportClosed:
            self.transport.send(traceback.format_exc().encode())

    def leak_labels_in_burn_instant(self, bundle_id):
        # the burn instant must carry ids/counters, never the bundle's
        # label material
        obs.instant("resilience.burn", bundle=bundle_id,
                    labels=self.gcirc.input_zero.tobytes())

    def retry_classname_ok(self, frame):
        # the shipped discipline: class name only, plus counters
        try:
            self.transport.send(frame)
        except TransportClosed as e:
            self.transport.send(
                f"error {type(e).__name__} (see local log)".encode())

    def burn_instant_ok(self, bundle_id, attempt):
        obs.instant("resilience.burn", bundle=bundle_id, attempt=attempt)

"""Deliberately leaky tracing call sites — secret-flow linter fixture.

Traces are exported artifacts (Chrome JSON on disk, CI artifacts), so a
span attribute is a log-grade exfiltration channel. Each ``leak_*``
method seeds one ``secret-to-span`` violation; the ``span_*_ok`` methods
record exactly the size/tag/count attributes the instrumented runtime
uses and must stay quiet. Linted by path only, never imported.
"""

from repro import obs


class LeakySpans:
    def __init__(self, gcirc, rng):
        self.gcirc = gcirc
        self.rng = rng

    def leak_labels_to_span(self, net):
        # label bytes as a span attribute: decodes the whole circuit once
        # the trace file leaves the machine
        with obs.span("garble", netlist=net.name,
                      labels=self.gcirc.input_zero.tobytes()):
            pass

    def leak_delta_to_instant(self):
        obs.instant("wire:seg", r=self.gcirc.r.tobytes())

    def leak_mask_via_arith_to_timer(self, t):
        # taint must survive the arithmetic rewrite of the mask
        masks = self.rng.integers(0, t, 8, dtype="uint64")
        negated = (t - masks) % t
        sp = obs.timer("prep", mask0=int(negated[0]))
        sp.close()

    def span_sizes_ok(self, net, seg):
        # the shipped instrumentation: names, counts and byte sizes of
        # public projections — must NOT be flagged
        with obs.span("gc_offline", netlist=net.name,
                      and_gates=net.and_count,
                      table_bytes=int(self.gcirc.tables.size) * 4):
            obs.instant("wire:seg", tag=seg.tag, bytes=len(seg.data))

    def span_counts_ok(self, n):
        with obs.span("offline", bundles=n, role="garbler"):
            pass

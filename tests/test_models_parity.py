"""Numerical parity of the optimized model paths against naive oracles:

  * flash-chunked attention == full softmax attention
  * chunked linear RNN (SSD/mLSTM) == per-step recurrence
  * prefill + decode == full-context forward (KV-cache correctness)
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced_config
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import forward, init_caches, init_params


def test_flash_equals_full_attention(rng):
    cfg = reduced_config(get_config("llama3.2-1b"), attn_chunk=16)
    B, Sq, H, KV, hd = 2, 64, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sq, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sq, KV, hd)), jnp.float32)
    out = L._flash_chunks(cfg, q, k, v, 0, True)
    # naive full attention oracle
    qpk = H // KV
    kx = jnp.repeat(k, qpk, axis=2)
    vx = jnp.repeat(v, qpk, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kx) / np.sqrt(hd)
    mask = np.tril(np.ones((Sq, Sq), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_chunked_linear_rnn_equals_stepwise(rng):
    B, Lh, H, N, P = 2, 32, 3, 4, 5
    C = jnp.asarray(rng.standard_normal((B, Lh, H, N)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, Lh, H, N)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((B, Lh, H, P)), jnp.float32)
    ld = jnp.asarray(-np.abs(rng.standard_normal((B, Lh, H))) * 0.3,
                     jnp.float32)
    y_chunk, s_chunk = S.chunked_linear_rnn(C, Bm, X, ld, chunk=8)
    state = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    for t in range(Lh):
        y, state = S.linear_rnn_step(C[:, t], Bm[:, t], X[:, t], ld[:, t],
                                     state)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


def test_chunked_rnn_state_chaining(rng):
    """Splitting a sequence across two calls with carried state == one call."""
    B, Lh, H, N, P = 1, 16, 2, 3, 4
    C = jnp.asarray(rng.standard_normal((B, Lh, H, N)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, Lh, H, N)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((B, Lh, H, P)), jnp.float32)
    ld = jnp.asarray(-np.abs(rng.standard_normal((B, Lh, H))) * 0.2,
                     jnp.float32)
    y_full, s_full = S.chunked_linear_rnn(C, Bm, X, ld, chunk=4)
    h = Lh // 2
    y1, s1 = S.chunked_linear_rnn(C[:, :h], Bm[:, :h], X[:, :h], ld[:, :h],
                                  chunk=4)
    y2, s2 = S.chunked_linear_rnn(C[:, h:], Bm[:, h:], X[:, h:], ld[:, h:],
                                  chunk=4, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-1.7b", "zamba2-2.7b",
                                  "xlstm-125m", "olmoe-1b-7b"])
def test_prefill_then_decode_matches_full(arch, rng):
    """Decode with a cache must reproduce the full-context logits."""
    cfg = reduced_config(get_config(arch), attn_chunk=16)
    cfg = dataclasses.replace(cfg, dtype="float32")
    B, Sq = 1, 17
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks = rng.integers(0, cfg.vocab_size, (B, Sq)).astype(np.int32)
    # full prefill on all Sq tokens -> logits for last position
    full_logits, _ = forward(cfg, params, {"tokens": jnp.asarray(toks)},
                             mode="prefill")
    # prefill on Sq-1, then decode the last token
    pre_logits, caches = forward(
        cfg, params, {"tokens": jnp.asarray(toks[:, :-1])}, mode="prefill"
    )
    # grow caches to hold one more token
    def grow(x, axis=2):
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, 4)
        return jnp.pad(x, pad)

    layers = caches["layers"]
    if "attn" in layers:
        layers = dict(layers)
        layers["attn"] = {k: grow(v) for k, v in layers["attn"].items()}
    caches = {"layers": layers, "len": caches["len"]}
    dec_logits, _ = forward(
        cfg, params, {"tokens": jnp.asarray(toks[:, -1:])}, mode="decode",
        caches=caches,
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )

"""Fault injection + resilient sessions (ISSUE 10).

Covers the TransportTimeout/TransportClosed split, deterministic seeded
fault schedules (same seed => same faults => same outcome on InProcPipe
AND TcpTransport), burn-on-interrupt bundle semantics, reconnect/resume
against a lease-holding gateway, and a seeded chaos sweep where every
schedule either completes bit-identical or fails with a typed error —
no hangs, no bundle reuse, no secret bytes on error/CONTROL frames.
"""

import re
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.net import (
    Deadlines,
    Fault,
    FaultPlan,
    FaultSchedule,
    FaultyTransport,
    GarblerEndpoint,
    InProcPipe,
    PitNetServer,
    ResilientClient,
    RetryPolicy,
    SessionLost,
    TcpListener,
    TcpTransport,
    TransportClosed,
    TransportTimeout,
)
from repro.net import wire as W
from repro.serve import BundlePoolEmpty, PitGateway

D, HEADS, DFF, S = 8, 2, 16, 4


def _model(seed=0):
    rng = np.random.default_rng(seed)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=6)
    return PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=seed)


# ---------------------------------------------------------------------------
# the timeout/closed split, per transport
# ---------------------------------------------------------------------------


def test_inproc_recv_timeout_is_typed():
    a, b = InProcPipe.make_pair()
    with pytest.raises(TransportTimeout):
        a.recv(timeout=0.05)
    # the split subclasses: every legacy `except TransportClosed` path
    # still catches a timeout
    assert issubclass(TransportTimeout, TransportClosed)
    b.close()
    with pytest.raises(TransportClosed) as ei:
        a.recv(timeout=1.0)
    assert not isinstance(ei.value, TransportTimeout)  # closed, not slow


def test_tcp_recv_timeout_is_typed():
    lst = TcpListener()
    raw = socket.create_connection(("127.0.0.1", lst.port))
    srv = lst.accept(timeout=5)
    # silence on a frame boundary: recoverable slowness
    with pytest.raises(TransportTimeout):
        srv.recv(timeout=0.05)
    # a torn length prefix: 2 of 4 header bytes then silence — framing
    # is lost, so this must be a hard close, not a retryable timeout
    raw.sendall(struct.pack(">I", 64)[:2])
    time.sleep(0.05)
    with pytest.raises(TransportClosed) as ei:
        srv.recv(timeout=0.2)
    assert not isinstance(ei.value, TransportTimeout)
    raw.close()
    srv.close()
    lst.close()


def test_deadlines_per_phase():
    dl = Deadlines(hello_s=1.0, online_s=3.0, default_s=9.0)
    assert dl.for_phase("hello") == 1.0
    assert dl.for_phase("online") == 3.0
    assert dl.for_phase("offline") == 9.0  # unset phase -> default
    assert dl.for_phase("idle") == 9.0
    u = Deadlines.uniform(7.0)
    assert all(u.for_phase(p) == 7.0
               for p in ("hello", "offline", "online", "idle"))


# ---------------------------------------------------------------------------
# schedules: seeded, deterministic, replayable
# ---------------------------------------------------------------------------


def test_schedule_from_seed_deterministic():
    for seed in range(20):
        s1 = FaultSchedule.from_seed(seed, n_faults=3, horizon=48)
        s2 = FaultSchedule.from_seed(seed, n_faults=3, horizon=48)
        assert s1 == s2
        assert all(f.op >= 2 for f in s1.faults)  # first_op spared
        assert all(f.kind in ("reset", "stall", "torn", "dup")
                   for f in s1.faults)
    assert FaultSchedule.from_seed(1) != FaultSchedule.from_seed(2)


def test_fault_plan_goes_clean_after_faulty_conns():
    plan = FaultPlan(seed=3, faulty_conns=2, n_faults=2)
    assert len(plan.schedule_for(0)) == 2
    assert len(plan.schedule_for(1)) == 2
    assert len(plan.schedule_for(2)) == 0  # chaos runs terminate
    assert plan.schedule_for(0) == FaultPlan(
        seed=3, faulty_conns=2, n_faults=2).schedule_for(0)


def test_stall_outliving_timeout_raises_transport_timeout():
    a, b = InProcPipe.make_pair()
    ft = FaultyTransport(a, FaultSchedule((Fault(0, "stall", 5.0),)))
    b.send(b"late frame")
    t0 = time.monotonic()
    with pytest.raises(TransportTimeout):
        ft.recv(timeout=0.1)  # stall outlives the deadline
    assert time.monotonic() - t0 < 1.0  # slept the timeout, not the stall
    assert ft.injected == [(0, "stall")]
    a.close()
    b.close()


def test_short_stall_delivers_late():
    a, b = InProcPipe.make_pair()
    ft = FaultyTransport(a, FaultSchedule((Fault(0, "stall", 0.05),)))
    b.send(b"frame")
    assert ft.recv(timeout=1.0) == b"frame"
    a.close()
    b.close()


def test_dup_fault_delivers_frame_twice():
    a, b = InProcPipe.make_pair()
    ft = FaultyTransport(a, FaultSchedule((Fault(0, "dup"),)))
    b.send(b"once")
    b.send(b"next")
    assert ft.recv(timeout=1.0) == b"once"
    assert ft.recv(timeout=1.0) == b"once"  # the duplicate delivery
    assert ft.recv(timeout=1.0) == b"next"
    a.close()
    b.close()


# ---------------------------------------------------------------------------
# cross-transport determinism: same schedule, same faults, same outcome
# ---------------------------------------------------------------------------


def _session_endpoints(model, kind, schedule, *, record=False):
    """(faulty client endpoint, cleanup) over transport ``kind``."""
    srv = PitNetServer(model, S, impl="ref")
    if kind == "inproc":
        a, b = InProcPipe.make_pair()
        srv.serve_transport(b, timeout=60)
        ft = FaultyTransport(a, schedule, record_frames=record)
        cli = GarblerEndpoint(ft, seed=7, impl="ref", timeout=60)
        return cli, ft, lambda: a.close()
    lst = TcpListener()
    loop = srv.serve_tcp(lst, timeout=60)
    ft = FaultyTransport(TcpTransport.connect("127.0.0.1", lst.port),
                         schedule, record_frames=record)
    cli = GarblerEndpoint(ft, seed=7, impl="ref", timeout=60)
    loop.wait_accepted(1, timeout=30)

    def cleanup():
        ft.close()
        lst.close()

    return cli, ft, cleanup


def _faulted_prep(model, kind, schedule):
    cli, ft, cleanup = _session_endpoints(model, kind, schedule)
    try:
        cli.preprocess(1)
        return "ok", list(ft.injected)
    except (TransportClosed, W.WireError, Exception) as e:
        return type(e).__name__, list(ft.injected)
    finally:
        cleanup()


@pytest.mark.parametrize("fault", [Fault(5, "reset"), Fault(7, "torn")])
def test_fatal_fault_identical_on_inproc_and_tcp(fault):
    model = _model(seed=61)
    schedule = FaultSchedule((fault,))
    out_inproc = _faulted_prep(model, "inproc", schedule)
    out_tcp = _faulted_prep(model, "tcp", schedule)
    # the endpoints walk the protocol in lockstep, so the k-th transport
    # op is the same op on both transports: identical fault log AND
    # identical typed outcome
    assert out_inproc == out_tcp
    assert out_inproc[1] == [(fault.op, fault.kind)]
    assert out_inproc[0] != "ok"


def test_benign_stall_bit_identical_on_inproc_and_tcp():
    model = _model(seed=62)
    rng = np.random.default_rng(63)
    x = rng.normal(0, 1, (S, D))
    sess = model.compile_session(S, impl="ref")
    y_ref = sess.run(x, sess.preprocess(1)[0])
    schedule = FaultSchedule((Fault(4, "stall", 0.05),))
    for kind in ("inproc", "tcp"):
        cli, ft, cleanup = _session_endpoints(model, kind, schedule)
        try:
            cli.preprocess(1)
            y = cli.run(x)
            assert np.array_equal(y, y_ref), kind
            assert ft.injected == [(4, "stall")], kind
        finally:
            cleanup()


# ---------------------------------------------------------------------------
# resilient client: reconnect, resume, burn-on-interrupt
# ---------------------------------------------------------------------------


def _gateway_identity(st):
    assert st["bundles_prepped"] == (
        st["bundles_consumed"] + st["bundles_outstanding"]
        + st["bundles_returned"] + st["bundles_burned"]), st


def test_resilient_reconnect_resumes_and_burns():
    """A forced reset mid-run: the interrupted bundle is burned on both
    sides, the client reconnects into the SAME session (lease held), and
    the retried run — on a fresh bundle — is bit-identical."""
    model = _model(seed=71)
    gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=4,
                    lease_s=30.0)
    dl = Deadlines.uniform(15.0)
    schedules = {1: FaultSchedule((Fault(8, "reset"),))}  # online leg
    conns = [0]

    def connect():
        c, s = InProcPipe.make_pair()
        gw.serve_transport(s, deadlines=dl)
        i = conns[0]
        conns[0] += 1
        return FaultyTransport(c, schedules.get(i, FaultSchedule()))

    cli = ResilientClient(connect, seed=5,
                          policy=RetryPolicy(attempts=6, base_s=0.02),
                          deadlines=dl)
    rng = np.random.default_rng(72)
    x = rng.normal(0, 1, (S, D))
    cli.preprocess(2)
    y = cli.run(x)
    sess = model.compile_session(S, impl="ref")
    y_ref = sess.run(x, sess.preprocess(1)[0])
    assert np.array_equal(y, y_ref)

    cst = cli.stats()
    assert cst["reconnects"] == 1 and cst["resume_handshakes"] == 1
    assert cst["bundles_burned"] == 1
    st = gw.stats()
    assert st["sessions_resumed"] == 1 and st["bundles_burned"] == 1
    assert [s["epoch"] for s in st["sessions"]] == [1]
    _gateway_identity(st)

    # the resumed session keeps serving bit-identically
    assert np.array_equal(cli.run(x), y_ref)
    cli.close()  # clean bye: immediate reclaim despite the lease
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline and gw.stats()["sessions_active"]:
        time.sleep(0.05)
    st = gw.stats()
    assert st["sessions_active"] == 0 and st["sessions_parked"] == 0
    _gateway_identity(st)
    gw.close()


def test_interrupted_prep_retried_with_fresh_ids():
    """A reset mid-prep: nothing is committed on either side, and the
    retry lands new bundle ids — no id collision, no phantom bundles."""
    model = _model(seed=73)
    gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=4,
                    lease_s=30.0)
    dl = Deadlines.uniform(15.0)
    schedules = {0: FaultSchedule((Fault(6, "reset"),))}  # offline leg
    conns = [0]

    def connect():
        c, s = InProcPipe.make_pair()
        gw.serve_transport(s, deadlines=dl)
        i = conns[0]
        conns[0] += 1
        return FaultyTransport(c, schedules.get(i, FaultSchedule()))

    cli = ResilientClient(connect, seed=6,
                          policy=RetryPolicy(attempts=6, base_s=0.02),
                          deadlines=dl)
    ids = cli.preprocess(1)
    assert len(ids) == 1 and cli.pool_size() == 1
    st = gw.stats()
    assert st["bundles_prepped"] == 1  # the torn prep never committed
    assert st["bundles_burned"] == 0  # prep interruption burns nothing
    _gateway_identity(st)
    rng = np.random.default_rng(74)
    x = rng.normal(0, 1, (S, D))
    sess = model.compile_session(S, impl="ref")
    assert np.array_equal(cli.run(x), sess.run(x, sess.preprocess(1)[0]))
    cli.close()
    gw.close()


def test_lease_expiry_reclaims_and_surfaces_session_lost():
    """A crashed client that stays away past its lease loses the
    session: bundles return to the identity, and a late resume attempt
    fails typed (SessionLost), never silently rebinding."""
    model = _model(seed=75)
    gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=4,
                    lease_s=0.2)
    dl = Deadlines.uniform(15.0)

    def connect():
        c, s = InProcPipe.make_pair()
        gw.serve_transport(s, deadlines=dl)
        return c

    cli = ResilientClient(connect, seed=7,
                          policy=RetryPolicy(attempts=3, base_s=0.02),
                          deadlines=dl)
    cli.preprocess(1)
    # crash: both transports vanish, no bye
    cli.offline.transport.close()
    cli.online.transport.close()
    cli._teardown()

    deadline = time.monotonic() + 10
    while time.monotonic() < deadline and not gw.stats()["leases_expired"]:
        time.sleep(0.05)
    st = gw.stats()
    assert st["leases_expired"] == 1 and st["sessions_parked"] == 0
    assert st["bundles_returned"] == 1  # the parked bundle came home
    _gateway_identity(st)

    rng = np.random.default_rng(76)
    with pytest.raises(SessionLost):
        cli.run(rng.normal(0, 1, (S, D)))
    cli.close()
    gw.close()


# ---------------------------------------------------------------------------
# seeded chaos sweep: every schedule completes bit-identical or fails typed
# ---------------------------------------------------------------------------

#: the only strings an error CONTROL frame may carry: a class name plus
#: a fixed parenthetical — never str(e), payload bytes, or tracebacks
_ERROR_WHITELIST = re.compile(
    r"^[A-Za-z_][A-Za-z0-9_]* \((idle deadline exceeded|"
    r"request deadline exceeded|see evaluator-side log)\)$")


def _audit_frames(plan):
    """Every decodable CONTROL frame that crossed a faulty transport:
    error payloads are class-name-only, per the secretflow discipline."""
    audited = 0
    for ft in plan.transports:
        for _direction, frame in ft.frame_log:
            try:
                msg = W.decode_frame(frame)
            except Exception:
                continue  # torn frames are expected to be undecodable
            if msg.kind != W.KIND_CONTROL:
                continue
            audited += 1
            if msg.tag == "error":
                assert isinstance(msg.payload, str), msg.payload
                assert _ERROR_WHITELIST.match(msg.payload), msg.payload
    return audited


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_chaos_sweep_typed_or_bit_identical():
    """~Dozen seeded schedules through a gateway run: each either
    completes bit-identical or fails with a typed error — no hangs, no
    bundle reuse, class-name-only error frames. Server endpoint threads
    are expected to die loudly on injected desyncs (duplicate frames
    land as unexpected CONTROL tags), hence the warning filter."""
    model = _model(seed=81)
    rng = np.random.default_rng(82)
    x = rng.normal(0, 1, (S, D))
    sess = model.compile_session(S, impl="ref")
    y_ref = sess.run(x, sess.preprocess(1)[0])
    dl = Deadlines.uniform(20.0)

    outcomes = {}
    audited_total = 0
    for seed in range(12):
        gw = PitGateway(model, S, impl="ref", max_sessions=4, pool_cap=4,
                        lease_s=30.0)
        plan = FaultPlan(seed=seed, faulty_conns=2, n_faults=1,
                         first_op=2, horizon=40, stall_s=0.05,
                         record_frames=True)

        def connect():
            c, s = InProcPipe.make_pair()
            gw.serve_transport(s, deadlines=dl)
            return plan.wrap(c)

        cli = ResilientClient(
            connect, seed=seed,
            policy=RetryPolicy(attempts=6, base_s=0.01, max_s=0.05,
                               seed=seed),
            deadlines=dl)
        try:
            cli.preprocess(1)
            y = cli.run(x)
            outcomes[seed] = ("ok" if np.array_equal(y, y_ref)
                              else "DIVERGED")
        except BundlePoolEmpty:
            outcomes[seed] = "BundlePoolEmpty"
        except TransportClosed as e:
            outcomes[seed] = type(e).__name__  # typed, incl. SessionLost
        finally:
            try:
                cli.close()
            except (TransportClosed, OSError):
                pass

        # replayability: the plan re-derives the exact schedules it ran
        for i, ft in enumerate(plan.transports):
            assert ft.schedule == plan.schedule_for(i), (seed, i)
        # the bundle identity holds whatever the faults did
        _gateway_identity(gw.stats())
        audited_total += _audit_frames(plan)
        gw.close()

    assert all(v != "DIVERGED" for v in outcomes.values()), outcomes
    allowed = {"ok", "BundlePoolEmpty", "TransportClosed",
               "TransportTimeout", "SessionLost"}
    assert set(outcomes.values()) <= allowed, outcomes
    # the sweep must actually exercise recovery, not sail through twelve
    # empty schedules — and the frame audit must have seen real traffic
    assert sum(1 for v in outcomes.values() if v == "ok") >= 6, outcomes
    assert audited_total > 0


def test_chaos_schedules_replay_identically():
    """Same seed => byte-for-byte the same fault schedule objects, the
    determinism the sweep's outcomes rest on."""
    for seed in range(12):
        p1 = FaultPlan(seed=seed, faulty_conns=2, n_faults=1, horizon=40)
        p2 = FaultPlan(seed=seed, faulty_conns=2, n_faults=1, horizon=40)
        for i in range(4):
            assert p1.schedule_for(i) == p2.schedule_for(i)

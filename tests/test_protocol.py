"""APINT protocol layers on shares: correctness + workload claims."""

import numpy as np
import pytest

from repro.config import PrivacyConfig
from repro.core import secret_sharing as SS
from repro.core.protocol import PiTProtocol


def _proto(frac=6, offload=True, seed=0):
    pcfg = PrivacyConfig(
        he_poly_n=256, he_num_primes=3, he_t_bits=40, frac_bits=frac,
        layernorm_offload=offload,
    )
    return PiTProtocol(pcfg, seed=seed)


@pytest.fixture(scope="module")
def P():
    return _proto()


def test_share_roundtrip(P, rng):
    x = rng.normal(0, 2, (4, 8))
    c, s = P.share_input(x)
    got = P.reveal(c, s)
    assert np.abs(got - x).max() < 2 ** -(P.frac - 1)


def test_linear_delphi(P, rng):
    W = rng.normal(0, 0.5, (6, 8))
    x = rng.normal(0, 1, 8)
    xc, xs = P.share_input(x)
    yc, ys = P.linear(W, xc, xs, use_he_offline=True)
    got = P.reveal(yc, ys, scale_bits=2 * P.frac)
    assert np.abs(got - W @ x).max() < 0.05


def test_beaver_matmul(P, rng):
    A = rng.normal(0, 1, (3, 5))
    B = rng.normal(0, 1, (5, 2))
    ac, as_ = P.share_input(A)
    bc, bs = P.share_input(B)
    zc, zs = P.matmul_private(ac, as_, bc, bs)
    got = P.reveal(zc, zs, scale_bits=2 * P.frac)
    assert np.abs(got - A @ B).max() < 0.1


def test_softmax_on_shares(P, rng):
    rows = rng.normal(0, 1.5, (3, 4))
    c, s = SS.share(rng, SS.encode_fx(rows, 2 * P.frac, P.t), P.t)
    oc, os_ = P.softmax_rows(c, s, 4, in_scale=2 * P.frac)
    got = P.reveal(oc, os_)
    want = np.exp(rows - rows.max(1, keepdims=True))
    want /= want.sum(1, keepdims=True)
    assert np.abs(got - want).max() < 0.05
    assert abs(got.sum(1) - 1).max() < 0.1


def test_gelu_on_shares(P, rng):
    from repro.core.circuits.nonlinear import _gelu

    x = rng.normal(0, 2, (2, 5))
    c, s = SS.share(rng, SS.encode_fx(x, 2 * P.frac, P.t), P.t)
    oc, os_ = P.activation("gelu", c, s, in_scale=2 * P.frac)
    want = np.vectorize(lambda v: _gelu(max(min(v, 4), -4)))(x)
    assert np.abs(P.reveal(oc, os_) - want).max() < 0.1


def test_layernorm_offload_matches_full(rng):
    x = rng.normal(0, 1, (2, 8))
    gamma = rng.normal(1, 0.1, 8)
    beta = rng.normal(0, 0.1, 8)
    mu = x.mean(1, keepdims=True)
    sd = np.sqrt(((x - mu) ** 2).mean(1, keepdims=True))
    want = (x - mu) / sd * gamma + beta
    outs = {}
    ands = {}
    for offload in (False, True):
        Pr = _proto(offload=offload, seed=1)
        c, s = SS.share(rng, SS.encode_fx(x, Pr.frac, Pr.t), Pr.t)
        oc, os_ = Pr.layernorm(c, s, gamma, beta, in_scale=Pr.frac)
        outs[offload] = Pr.reveal(oc, os_)
        ands[offload] = sum(v["and"] for v in Pr.stats.per_fn.values())
    assert np.abs(outs[False] - want).max() < 0.15
    assert np.abs(outs[True] - want).max() < 0.15
    # the paper's LayerNorm claim: the offload removes ~47% of GC work
    reduction = 1 - ands[True] / ands[False]
    assert 0.30 < reduction < 0.65, reduction


def test_comm_accounting(P):
    st = P.stats
    assert st.channel_offline.total > 0
    assert st.channel_online.total > 0
    assert st.gc_instances_ands > 0
    # offline carries tables + HE; online carries OT + openings
    assert any(k.startswith("tables") for k in st.channel_offline.by_tag)
    assert any(k.startswith("ot") for k in st.channel_online.by_tag)


def test_gc_truncation_exact(P, rng):
    """Deferred truncation inside GC is exact (floor division)."""
    x = rng.normal(0, 1, (1, 6))
    enc = SS.encode_fx(x, 2 * P.frac, P.t)
    c, s = SS.share(rng, enc, P.t)

    def body(cb, ins):
        return [ins[0]]

    net = P.build_fn_circuit("trunc_test", 1, 1, body, descale=P.frac)
    oc, os_ = P.gc_apply(net, c.reshape(-1, 1), s.reshape(-1, 1), 1)
    got = P.reveal(oc.reshape(1, 6), os_.reshape(1, 6))
    fx = np.round(x * (1 << 2 * P.frac))
    want = np.floor(fx / (1 << P.frac)) / (1 << P.frac)
    assert np.abs(got - want).max() < 1e-9

"""Two-party runtime: wire codec golden bytes, transports, end-to-end
parity with the in-process session (outputs bit-identical, per-tag wire
ledger == metered Channel oracle), and the pipelined serving mode."""

import hashlib
import threading

import numpy as np
import pytest

from repro.config import PrivacyConfig
from repro.core.engine import PrivateTransformer, random_weights
from repro.net import (
    GarblerEndpoint,
    InProcPipe,
    NetProtocolError,
    PitNetServer,
    TcpListener,
    TcpTransport,
)
from repro.net import wire as W
from repro.net.transport import TransportClosed
from repro.serve import BundlePoolEmpty, NetPrivateServeEngine, PrivateRequest

D, HEADS, DFF, S = 8, 2, 16, 4


def _model(seed=0, frac=6, offload=True):
    rng = np.random.default_rng(seed)
    weights = random_weights(rng, D, DFF, 1)
    pcfg = PrivacyConfig(he_poly_n=256, he_num_primes=3, he_t_bits=40,
                         frac_bits=frac, layernorm_offload=offload)
    return PrivateTransformer(pcfg, D, HEADS, DFF, weights, seed=seed)


def _pipe_pair(model, *, impl="ref", seed=7, timeout=300):
    srv = PitNetServer(model, S, impl=impl)
    a, b = InProcPipe.make_pair()
    srv.serve_transport(b, timeout=timeout)
    cli = GarblerEndpoint(a, seed=seed, impl=impl, timeout=timeout)
    return cli, srv


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------


def test_wire_roundtrip_typed():
    payload = {
        "none": None, "t": True, "f": False, "i": -(1 << 70), "fl": 2.5,
        "s": "softmax4", "b": b"\x00\x01", "l": [1, "two", None],
        "a": np.arange(12, dtype=np.uint64).reshape(3, 4),
    }
    msg = W.decode_frame(W.encode_msg(W.KIND_CONTROL, "hello", payload))
    assert msg.kind == W.KIND_CONTROL and msg.tag == "hello"
    got = msg.payload
    for k in ("none", "t", "f", "i", "fl", "s", "b", "l"):
        assert got[k] == payload[k], k
    assert np.array_equal(got["a"], payload["a"])
    assert got["a"].dtype == np.uint64


def test_wire_proto_segs_roundtrip():
    segs = [W.Seg("tables:softmax4", W.DIR_C2S, b"\x01" * 32),
            W.Seg("g-labels", W.DIR_S2C, b"")]
    msg = W.decode_frame(W.encode_proto(segs, W.PHASE_OFFLINE))
    assert msg.kind == W.KIND_PROTO and msg.phase == W.PHASE_OFFLINE
    assert [(s.tag, s.dir, s.data) for s in msg.segs] == \
        [(s.tag, s.dir, s.data) for s in segs]


def test_wire_golden_bytes():
    """The encoding is deterministic and versioned: same message, same
    bytes, forever (bump WIRE_VERSION when the layout changes)."""
    frame = W.encode_msg(
        W.KIND_SIM, "gc-meta:softmax4",
        {"perm": np.arange(6, dtype=np.uint32).reshape(2, 3),
         "n": 42, "name": "softmax4"},
        phase=W.PHASE_OFFLINE)
    assert frame[:2] == b"PW" and frame[2] == W.WIRE_VERSION
    assert hashlib.sha256(frame).hexdigest() == (
        "49f279af8581e90b783ace4921d4acbe8d970dd60311f0b42d3caa276f015427")


def test_wire_version_rejected():
    frame = bytearray(W.encode_msg(W.KIND_CONTROL, "hello", None))
    frame[2] = max(W.SUPPORTED_VERSIONS) + 1
    with pytest.raises(W.WireError):
        W.decode_frame(bytes(frame))


def test_wire_v2_golden_seed_stream():
    """v2 PROTO framing is golden too: a seed-stream segment is a fixed
    32-byte (seed, counter, count) record, byte-stable forever."""
    seg = W.Seg("g-labels", W.DIR_C2S,
                W.pack_seed_stream(bytes(range(16)), 7, 1234))
    frame = W.encode_proto([seg], W.PHASE_OFFLINE, version=W.WIRE_V2)
    assert frame[:2] == b"PW" and frame[2] == W.WIRE_V2
    assert len(seg.data) == W.SEED_STREAM_BYTES
    assert hashlib.sha256(frame).hexdigest() == (
        "1ebcf99cb75583a86a6d8000ae1e3716edf25e4231a1b514eb8f24d9c8d9cb45")
    msg = W.decode_frame(frame)
    assert msg.version == W.WIRE_V2
    assert W.unpack_seed_stream(msg.segs[0].data) == \
        (bytes(range(16)), 7, 1234)
    with pytest.raises(W.WireError):
        W.unpack_seed_stream(seg.data + b"\x00")


def test_wire_tables_delta_roundtrip():
    """Delta batches are lossless and exactly the modeled sizes."""
    rng = np.random.default_rng(0)
    for inst, n_and in ((1, 5), (4, 1), (16, 33)):
        tables = rng.integers(0, 1 << 32, (inst, max(n_and, 1), 2, 4),
                              dtype=np.uint32)
        wire, resid = W.pack_tables_delta(tables)
        assert len(wire) == W.tables_delta_wire_bytes(inst, n_and)
        assert len(resid) == W.tables_resid_bytes(inst, n_and)
        got = W.unpack_tables_delta(wire, resid, inst, n_and)
        assert np.array_equal(got, tables)
    with pytest.raises(W.WireError):
        W.unpack_tables_delta(wire, resid, inst + 1, n_and)


def test_wire_packers_meter_sizes():
    """Payload lengths are exactly what the in-process meter counts."""
    from repro.core.ot import ot_request_bytes, ot_response_bytes

    arr = np.arange(10, dtype=np.uint64).reshape(2, 5)
    assert len(W.pack_u64(arr)) == arr.size * 8  # shares: size*8
    assert np.array_equal(W.unpack_u64(W.pack_u64(arr), arr.shape), arr)

    lab = np.arange(2 * 3 * 4, dtype=np.uint32).reshape(2, 3, 4)
    assert len(W.pack_labels(lab)) == 2 * 3 * 16  # labels: 16B each
    assert np.array_equal(W.unpack_labels(W.pack_labels(lab), (2, 3)), lab)

    bits = np.array([[1, 0, 1], [0, 1, 1]], np.uint8)
    req = W.pack_ot_request(bits)
    assert len(req) == ot_request_bytes(bits.size)
    assert np.array_equal(W.unpack_ot_request(req, bits.shape), bits)
    resp = W.pack_ot_response(lab)
    assert len(resp) == ot_response_bytes(6)
    assert np.array_equal(W.unpack_ot_response(resp, (2, 3)), lab)

    # identity-HE ct framing: ceil(size/poly_n) blocks of ct_bytes
    ct_bytes, poly_n = 2 * 3 * 16 * 8, 16
    data = W.ct_pack(arr, ct_bytes, poly_n)
    assert len(data) == W.ct_blocks(arr.size, poly_n) * ct_bytes
    assert np.array_equal(W.ct_unpack(data, arr.shape), arr)
    rows = np.arange(3, dtype=np.uint64)
    blk = W.ct_pack_rows(rows, ct_bytes)
    assert len(blk) == 3 * ct_bytes  # one ct per row (he-cross shape)
    assert np.array_equal(W.ct_unpack_rows(blk, 3, ct_bytes), rows)


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------


def test_inproc_pipe_duplex_and_close():
    a, b = InProcPipe.make_pair()
    a.send(b"ping")
    assert b.recv(timeout=5) == b"ping"
    b.send(b"pong")
    assert a.recv(timeout=5) == b"pong"
    assert a.bytes_sent == 4 and a.bytes_recv == 4
    a.close()
    with pytest.raises(TransportClosed):
        b.recv(timeout=5)


def test_tcp_transport_frames_and_timeout():
    lst = TcpListener()
    got = {}

    def server():
        t = lst.accept(timeout=10)
        got["frame"] = t.recv(timeout=10)
        t.send(b"y" * 100_000)  # bigger than one socket buffer read
        t.close()

    th = threading.Thread(target=server)
    th.start()
    cli = TcpTransport.connect("127.0.0.1", lst.port)
    cli.send(b"x" * 70_000)
    assert cli.recv(timeout=10) == b"y" * 100_000
    th.join(timeout=10)
    assert got["frame"] == b"x" * 70_000
    with pytest.raises(TransportClosed):
        cli.recv(timeout=0.2)  # nothing more coming: hard fail, no hang
    cli.close()
    lst.close()


# ---------------------------------------------------------------------------
# end-to-end: InProcPipe (shared transcript fixture)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def netrun():
    """One two-party transcript (preprocess 2 + 2 runs) next to the
    in-process metered oracle running the identical workload."""
    model = _model()
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (S, D))

    cli, srv = _pipe_pair(model)
    cli.handshake()
    ids = cli.preprocess(2)
    y1 = cli.run(x)
    y2 = cli.run(x)

    sess = model.compile_session(S, impl="ref", wire_version=2)
    bundles = sess.preprocess(2)
    y_ref1 = sess.run(x, bundles[0])
    y_ref2 = sess.run(x, bundles[1])
    return dict(model=model, cli=cli, srv=srv, x=x, ids=ids,
                y=(y1, y2), y_ref=(y_ref1, y_ref2), oracle=sess.stats)


def test_net_output_bit_identical(netrun):
    assert np.array_equal(netrun["y"][0], netrun["y_ref"][0])
    assert np.array_equal(netrun["y"][1], netrun["y_ref"][1])
    want = netrun["model"].forward_float(netrun["x"])
    assert np.abs(netrun["y"][0] - want).max() < 0.25


def test_net_ledger_matches_metered_oracle(netrun):
    """Per-phase, per-tag wire bytes == the in-process Channel meter."""
    led = netrun["cli"].shared.ledger
    st = netrun["oracle"]
    assert led.offline.by_tag == dict(st.channel_offline.by_tag)
    assert led.online.by_tag == dict(st.channel_online.by_tag)
    assert led.offline.total == st.channel_offline.total
    assert led.online.total == st.channel_online.total
    # both endpoints saw the same traffic
    sled = netrun["srv"].shared.ledger
    assert sled.offline.by_tag == led.offline.by_tag
    assert sled.online.by_tag == led.online.by_tag
    # the sim sideband (decode metadata, reveal) is small and separate
    # once the v2 table-delta residual (a modeled stand-in, like the
    # identity-HE padding) is taken out
    assert led.resid_bytes > 0
    assert 0 < led.sim_bytes - led.resid_bytes \
        < 0.02 * (led.offline.total + led.online.total)


def test_net_v2_negotiated_and_coalesced(netrun):
    """The pipe pair negotiated v2+compression, streamed seeds, delta
    batches, and coalesced same-direction segments into fewer frames."""
    cli, srv = netrun["cli"], netrun["srv"]
    assert cli.shared.negotiated_version == W.WIRE_V2
    assert cli.shared.negotiated_compression is True
    led = cli.shared.ledger
    s = led.summary()
    assert led.seed_stream_segs > 0
    assert led.delta_batches > 0
    # coalescing: strictly fewer wire flushes than metered messages,
    # and per-phase PROTO flip counts never exceed the global count
    # (which also sees the CONTROL handshake frames)
    assert s["rounds_after_coalescing"] < s["raw_messages"]
    assert s["dir_flips_offline"] + s["dir_flips_online"] <= s["dir_flips"]
    # a coalesced flush carries its segments verbatim: per-tag ledger
    # bytes (recorded seg-by-seg at flush) sum to the phase totals
    assert sum(led.offline.by_tag.values()) == led.offline.total
    assert sum(led.online.by_tag.values()) == led.online.total
    # both ends agree on the coalesced round structure
    ss = srv.shared.ledger.summary()
    assert ss["rounds_after_coalescing"] == s["rounds_after_coalescing"]
    assert ss["dir_flips_offline"] == s["dir_flips_offline"]
    assert ss["dir_flips_online"] == s["dir_flips_online"]


def test_net_bundle_consumed_and_unknown(netrun):
    cli = netrun["cli"]
    with pytest.raises(NetProtocolError):
        cli.run(netrun["x"], bundle_id=netrun["ids"][0])  # consumed
    with pytest.raises(NetProtocolError):
        cli.run(netrun["x"])  # pool drained by the fixture's two runs


# ---------------------------------------------------------------------------
# end-to-end: loopback TCP + full-GC LayerNorm variant
# ---------------------------------------------------------------------------


def test_net_tcp_end_to_end():
    model = _model(seed=3)
    rng = np.random.default_rng(4)
    x = rng.normal(0, 1, (S, D))
    srv = PitNetServer(model, S, impl="ref")
    lst = TcpListener()
    loop = srv.serve_tcp(lst, timeout=300)
    cli = GarblerEndpoint(TcpTransport.connect("127.0.0.1", lst.port),
                          seed=9, impl="ref", timeout=300)
    assert loop.wait_accepted(1, timeout=30)
    cli.preprocess(1)
    y = cli.run(x)
    sess = model.compile_session(S, impl="ref", wire_version=2)
    assert np.array_equal(y, sess.run(x, sess.preprocess(1)[0]))
    led = cli.shared.ledger
    st = sess.stats
    assert led.offline.by_tag == dict(st.channel_offline.by_tag)
    assert led.online.by_tag == dict(st.channel_online.by_tag)
    cli.close()
    lst.close()


def test_net_v1_peer_negotiates_down():
    """A v1-pinned client against a v2 server: the hello negotiates the
    session down to v1 and the run completes with v1 byte accounting."""
    model = _model(seed=21)
    rng = np.random.default_rng(22)
    x = rng.normal(0, 1, (S, D))
    srv = PitNetServer(model, S, impl="ref")
    a, b = InProcPipe.make_pair()
    srv.serve_transport(b, timeout=300)
    cli = GarblerEndpoint(a, seed=23, impl="ref", timeout=300,
                          wire_version=1)
    cli.preprocess(1)
    y = cli.run(x)
    assert cli.shared.negotiated_version == 1
    assert cli.shared.negotiated_compression is False
    sess = model.compile_session(S, impl="ref")  # v1 oracle
    assert np.array_equal(y, sess.run(x, sess.preprocess(1)[0]))
    led = cli.shared.ledger
    st = sess.stats
    assert led.offline.by_tag == dict(st.channel_offline.by_tag)
    assert led.online.by_tag == dict(st.channel_online.by_tag)
    assert led.seed_stream_segs == 0 and led.delta_batches == 0
    cli.close()


def test_net_full_gc_layernorm():
    """--no-offload path: γ/β enter the circuit via the evaluator's OT."""
    model = _model(seed=5, offload=False)
    rng = np.random.default_rng(6)
    x = rng.normal(0, 1, (S, D))
    cli, _ = _pipe_pair(model, seed=11)
    cli.preprocess(1)
    y = cli.run(x)
    sess = model.compile_session(S, impl="ref", wire_version=2)
    assert np.array_equal(y, sess.run(x, sess.preprocess(1)[0]))
    led = cli.shared.ledger
    st = sess.stats
    assert led.offline.by_tag == dict(st.channel_offline.by_tag)
    assert led.online.by_tag == dict(st.channel_online.by_tag)
    cli.close()


# ---------------------------------------------------------------------------
# pipelined serving: dedicated offline pair + online pair
# ---------------------------------------------------------------------------


def test_net_pipelined_refill_overlaps_serving():
    model = _model(seed=8)
    rng = np.random.default_rng(9)
    srv = PitNetServer(model, S, impl="ref")
    off_c, off_s = InProcPipe.make_pair()
    on_c, on_s = InProcPipe.make_pair()
    srv.serve_transport(off_s, timeout=300, name="pit-eval-offline")
    srv.serve_transport(on_s, timeout=300, name="pit-eval-online")
    eng = NetPrivateServeEngine(off_c, on_c, pool_target=2, seed=13,
                                impl="ref", timeout=300)
    eng.preprocess(1)
    assert eng.pool_size() == 1

    # hold back offline *responses* until serving is done: refill traffic
    # is in flight on its own endpoint pair the whole time
    gate = threading.Event()
    off_c.recv_gate = gate
    refill = eng.refill_async(1)
    req = PrivateRequest(x=rng.normal(0, 1, (S, D)))
    eng.serve([req])  # online pair unaffected by the gated offline pair
    assert req.result is not None
    assert refill.is_alive(), "refill should still be streaming"
    gate.set()
    refill.join(timeout=300)
    assert eng.pool_size() == 1

    # dry pool → clean load-shed signal
    eng.serve([PrivateRequest(x=rng.normal(0, 1, (S, D)))])
    with pytest.raises(BundlePoolEmpty):
        eng.serve([PrivateRequest(x=rng.normal(0, 1, (S, D)))])
    # maintain tops back up to pool_target over the offline pair
    assert eng.maintain() == 2
    # a bad request must not burn the bundle it claimed (rejected before
    # any wire traffic → returned to the pool, like the in-process engine)
    with pytest.raises(ValueError):
        eng.serve([PrivateRequest(x=rng.normal(0, 1, (S, D + 1)))])
    assert eng.pool_size() == 2
    eng.close()
